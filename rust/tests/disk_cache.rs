//! Persistent report-store integration: sweeps resume from disk across
//! "process restarts" (simulated by clearing the in-memory level),
//! concurrent sweeps share one store safely, and poisoned entries —
//! truncated, garbage, stale-version — silently degrade to recompute and
//! are rewritten, never panicking and never changing results.
//!
//! Every test uses its own store directory and its own seeds, so tests
//! stay hermetic against each other and against earlier `cargo test`
//! runs (the suite-wide contract the default `target/repro/cache` store
//! relies on is the build fingerprint, covered by the store's unit
//! tests).

use std::path::PathBuf;

use dlpim::config::SimConfig;
use dlpim::coordinator::report::SimReport;
use dlpim::policy::PolicyKind;
use dlpim::sweep::store::DiskStore;
use dlpim::sweep::{cache, DiskCache, Sweep, SweepPoint};

fn tiny(policy: PolicyKind, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::hmc();
    cfg.policy = policy;
    cfg.warmup_requests = 200;
    cfg.measure_requests = 1_500;
    cfg.epoch_cycles = 5_000;
    cfg.seed = seed;
    cfg
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dlpim-diskcache-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn fingerprint(r: &SimReport) -> (u64, u64, u64, u64) {
    let run = &r.runs[0];
    (
        run.cycles,
        run.stats.requests,
        run.stats.traffic.total_bytes(),
        run.stats.latency.total(),
    )
}

#[test]
fn warm_sweep_is_served_from_disk_across_memory_clear() {
    let dir = tmp_dir("warm");
    let point = SweepPoint::new("STRAdd", tiny(PolicyKind::Never, 0xD15C_0001));

    let first = Sweep::new(vec![point.clone()])
        .disk_cache(DiskCache::Dir(dir.clone()))
        .run();
    assert!(!first[0].from_cache, "cold run must compute");

    // The entry must be on disk already (flushed as the job completed).
    let store = DiskStore::at(&dir);
    assert!(
        store.load(point.key()).is_some(),
        "completed job must be persisted at {}",
        store.entry_path(point.key()).display()
    );

    // Drop the in-memory level: the next sweep models a fresh process
    // sharing the same store directory.
    cache::clear();
    let second = Sweep::new(vec![point.clone()])
        .disk_cache(DiskCache::Dir(dir.clone()))
        .run();
    assert!(second[0].from_cache, "warm run must schedule zero jobs");
    assert_eq!(
        fingerprint(first[0].report()),
        fingerprint(second[0].report()),
        "disk round-trip must be lossless"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn interrupted_sweep_resumes_from_completed_points() {
    let dir = tmp_dir("resume");
    let points: Vec<SweepPoint> = ["STRAdd", "STRCpy", "STRSca"]
        .iter()
        .map(|w| SweepPoint::new(*w, tiny(PolicyKind::Never, 0xD15C_0002)))
        .collect();

    // "Interrupted" run: only the middle point completed before the kill.
    let partial = Sweep::new(vec![points[1].clone()])
        .disk_cache(DiskCache::Dir(dir.clone()))
        .run();
    assert!(!partial[0].from_cache);

    cache::clear();
    let resumed = Sweep::new(points.clone())
        .disk_cache(DiskCache::Dir(dir.clone()))
        .run();
    assert!(!resumed[0].from_cache, "uncomputed point must simulate");
    assert!(resumed[1].from_cache, "completed point must resume from disk");
    assert!(!resumed[2].from_cache, "uncomputed point must simulate");
    assert_eq!(fingerprint(partial[0].report()), fingerprint(resumed[1].report()));

    // Every point is persisted now: a third pass is fully warm.
    cache::clear();
    let warm = Sweep::new(points).disk_cache(DiskCache::Dir(dir.clone())).run();
    assert!(warm.iter().all(|o| o.from_cache), "fully resumable");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn poisoned_entries_recompute_and_are_rewritten() {
    let dir = tmp_dir("poison");
    let point = SweepPoint::new("STRTriad", tiny(PolicyKind::Always, 0xD15C_0003));
    let store = DiskStore::at(&dir);

    let reference = Sweep::new(vec![point.clone()])
        .disk_cache(DiskCache::Dir(dir.clone()))
        .run();
    let reference_fp = fingerprint(reference[0].report());
    let path = store.entry_path(point.key());
    let good = std::fs::read_to_string(&path).expect("entry written");

    for (label, bad) in [
        ("truncated", good[..good.len() / 3].to_string()),
        ("garbage", "}{ not json []".to_string()),
        ("empty", String::new()),
        ("stale-version", good.replacen("\"format\":1", "\"format\":999", 1)),
    ] {
        std::fs::write(&path, &bad).unwrap();
        cache::clear();
        let out = Sweep::new(vec![point.clone()])
            .disk_cache(DiskCache::Dir(dir.clone()))
            .run();
        assert!(
            !out[0].from_cache,
            "{label}: a poisoned entry must fall back to recompute"
        );
        assert_eq!(
            fingerprint(out[0].report()),
            reference_fp,
            "{label}: recompute must reproduce the reference report"
        );
        // The poisoned entry must have been overwritten with a valid one.
        assert!(
            store.load(point.key()).is_some(),
            "{label}: entry must be rewritten after recompute"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn concurrent_sweeps_share_one_store_safely() {
    let dir = tmp_dir("race");
    let cfgs = [tiny(PolicyKind::Never, 0xD15C_0004), tiny(PolicyKind::Always, 0xD15C_0004)];
    let points: Vec<SweepPoint> = ["STRAdd", "STRCpy", "SPLRad", "HSJNPO"]
        .iter()
        .flat_map(|w| cfgs.iter().map(move |c| SweepPoint::new(*w, c.clone())))
        .collect();

    // Two sweeps over the same points race on the same directory: both
    // must complete with identical, valid reports — entries written by
    // one and read by the other must never tear (atomic rename).
    let (a, b) = std::thread::scope(|scope| {
        let pa = points.clone();
        let da = dir.clone();
        let ta = scope.spawn(move || {
            Sweep::new(pa).disk_cache(DiskCache::Dir(da)).threads(4).run()
        });
        let pb = points.clone();
        let db = dir.clone();
        let tb = scope.spawn(move || {
            Sweep::new(pb).disk_cache(DiskCache::Dir(db)).threads(4).run()
        });
        (ta.join().unwrap(), tb.join().unwrap())
    });

    assert_eq!(a.len(), points.len());
    assert_eq!(b.len(), points.len());
    for (oa, ob) in a.iter().zip(&b) {
        assert_eq!(oa.workload, ob.workload);
        assert_eq!(
            fingerprint(oa.report()),
            fingerprint(ob.report()),
            "racing sweeps must agree on {}",
            oa.workload
        );
    }

    // Whatever the interleaving, the store ends up fully populated with
    // entries this build can read back.
    let store = DiskStore::at(&dir);
    for p in &points {
        assert!(store.load(p.key()).is_some(), "{} entry readable", p.workload);
    }
    let stats = store.scan().unwrap();
    assert_eq!(stats.corrupt, 0, "no torn entries: {stats:?}");
    assert_eq!(stats.tmp, 0, "no leaked temp files: {stats:?}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn off_mode_neither_reads_nor_writes_the_store() {
    let dir = tmp_dir("off");
    let point = SweepPoint::new("STRAdd", tiny(PolicyKind::Never, 0xD15C_0005));

    // Seed the store, then run the same point with persistence off and a
    // cold memory level: it must recompute (no read) …
    let seeded = Sweep::new(vec![point.clone()])
        .disk_cache(DiskCache::Dir(dir.clone()))
        .run();
    assert!(!seeded[0].from_cache);
    cache::clear();
    let off = Sweep::new(vec![point.clone()]).disk_cache(DiskCache::Off).run();
    assert!(!off[0].from_cache, "Off mode must not read the store");

    // … and leave the store exactly as it was (one entry, no writes).
    let n = std::fs::read_dir(&dir).unwrap().count();
    assert_eq!(n, 1, "Off mode must not write the store");
    std::fs::remove_dir_all(&dir).ok();
}

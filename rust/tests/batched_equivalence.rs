//! Differential tests for the data-oriented batched driver.
//!
//! `simulate_once` (batched admission windows, SoA vaults, frame-buffered
//! stats) and `simulate_once_scalar` (the original one-event-at-a-time
//! heap loop, kept as the reference) must be *indistinguishable* from the
//! outside: identical seeds must produce identical `ServedRequest`
//! streams request-by-request, identical reports, and identical epoch
//! decisions — across every topology, both memory presets, and both ends
//! of the policy spectrum. `tests/golden_artifacts.rs` guards the figure
//! JSON bytes; these tests guard the mechanism underneath and localize a
//! divergence to the first differing request instead of a checksum.

use dlpim::config::{SimConfig, Topology};
use dlpim::coordinator::driver::{simulate_once_observed, simulate_once_scalar_observed};
use dlpim::memsys::{Access, ServedRequest};
use dlpim::policy::PolicyKind;
use dlpim::workloads::{catalog, Op, Workload};
use dlpim::CoreId;

type Stream = Vec<(Access, ServedRequest)>;

/// Run both drivers on identical seeds and return the captured streams
/// plus both reports, after asserting stream equality with a pinpointed
/// first-divergence message.
fn diff_drivers(
    cfg: &SimConfig,
    workload: &mut dyn Workload,
    label: &str,
) -> (Stream, dlpim::coordinator::RunReport, dlpim::coordinator::RunReport) {
    let mut batched: Stream = Vec::new();
    workload.reset(cfg.seed);
    let rep_b = simulate_once_observed(cfg, workload, |a, r| batched.push((a, *r)));

    let mut scalar: Stream = Vec::new();
    workload.reset(cfg.seed);
    let rep_s = simulate_once_scalar_observed(cfg, workload, |a, r| scalar.push((a, *r)));

    assert_eq!(
        batched.len(),
        scalar.len(),
        "{label}: request counts diverge (batched {} vs scalar {})",
        batched.len(),
        scalar.len()
    );
    for (i, (b, s)) in batched.iter().zip(scalar.iter()).enumerate() {
        assert_eq!(b, s, "{label}: first divergence at request #{i}");
    }
    (batched, rep_s, rep_b)
}

/// The full matrix the tentpole promises: every topology on both presets,
/// no-subscription baseline and the headline adaptive policy. Identical
/// streams and identical reports.
#[test]
fn batched_and_scalar_streams_identical_across_matrix() {
    for preset in ["hmc", "hbm"] {
        for topology in [Topology::Mesh, Topology::Crossbar, Topology::Ring] {
            for policy in [PolicyKind::Never, PolicyKind::Adaptive] {
                let mut cfg = SimConfig::preset(preset).unwrap();
                cfg.topology = topology;
                cfg.policy = policy;
                cfg.warmup_requests = 500;
                cfg.measure_requests = 3_000;
                cfg.runs = 1;
                cfg.validate().unwrap_or_else(|e| {
                    panic!("{preset}/{}: {}", topology.as_str(), e.join("; "))
                });
                let label =
                    format!("{preset}/{}/{}", topology.as_str(), policy.as_str());
                let mut w = catalog::build("SPLRad", &cfg).unwrap();
                let (stream, rep_s, rep_b) = diff_drivers(&cfg, w.as_mut(), &label);
                assert!(!stream.is_empty(), "{label}: no requests captured");
                assert_eq!(rep_b, rep_s, "{label}: reports diverge");
            }
        }
    }
}

/// Two batched runs on the same seed are bit-identical (the batched path
/// introduces no hidden iteration-order or allocation dependence).
#[test]
fn batched_driver_is_deterministic() {
    let mut cfg = SimConfig::hmc();
    cfg.policy = PolicyKind::Adaptive;
    cfg.warmup_requests = 500;
    cfg.measure_requests = 3_000;
    let mut w = catalog::build("PLYgemm", &cfg).unwrap();

    let mut run = || {
        let mut stream: Stream = Vec::new();
        w.reset(cfg.seed);
        let rep = simulate_once_observed(&cfg, w.as_mut(), |a, r| stream.push((a, *r)));
        (stream, rep)
    };
    let (s1, r1) = run();
    let (s2, r2) = run();
    assert_eq!(s1, s2);
    assert_eq!(r1, r2);
}

/// A deliberately skewed multi-core workload: core 0 issues back-to-back
/// while the rest idle through huge compute gaps, and every stream is
/// finite. This pins the measured-window accounting fixes (PR 5) under
/// batching: the window must end when the breaking core's *local* time
/// passes, not when the laggards drain, and exhaustion must be reported
/// identically by both drivers.
struct SkewedFinite {
    remaining: Vec<u64>,
    issued: Vec<u64>,
    n: u16,
}

impl SkewedFinite {
    fn new(n: u16) -> Self {
        SkewedFinite { remaining: vec![0; n as usize], issued: vec![0; n as usize], n }
    }
}

impl Workload for SkewedFinite {
    fn name(&self) -> &'static str {
        "SkewedFinite"
    }

    fn next_op(&mut self, core: CoreId) -> Option<Op> {
        let c = core as usize;
        if self.remaining[c] == 0 {
            return None;
        }
        self.remaining[c] -= 1;
        let i = self.issued[c];
        self.issued[c] += 1;
        // Core 0 streams over a region far larger than its 32 KB L1
        // (every access misses) with unit gaps; everyone else touches a
        // few private blocks separated by compute gaps big enough that a
        // 4096-cycle admission window never holds two of their events.
        let (addr, gap) = if core == 0 {
            (((i * 97) % 65_536) * 64, 1)
        } else {
            ((0x10_0000 * core as u64 + i) * 64, 200_000)
        };
        Some(Op { addr, write: i % 5 == 0, gap })
    }

    fn reset(&mut self, _seed: u64) {
        for c in 0..self.n as usize {
            // Core 0: the bulk of the traffic. Others: a trickle.
            self.remaining[c] = if c == 0 { 2_000 } else { 8 };
            self.issued[c] = 0;
        }
    }
}

#[test]
fn skewed_window_boundary_accounting_matches_scalar() {
    for policy in [PolicyKind::Never, PolicyKind::Adaptive] {
        let mut cfg = SimConfig::hmc();
        cfg.policy = policy;
        cfg.warmup_requests = 200;
        cfg.measure_requests = 1_500;
        cfg.runs = 1;
        let mut w = SkewedFinite::new(cfg.n_vaults);
        let label = format!("skewed/{}", policy.as_str());
        let (stream, rep_s, rep_b) = diff_drivers(&cfg, &mut w, &label);
        assert_eq!(rep_b, rep_s, "{label}: reports diverge");
        // The measured window closes on the breaking core's clock: the
        // laggards' 200k-cycle gaps must not inflate the measured cycles
        // (2000 unit-gap requests from core 0 end the window long before
        // the slow cores would drain their 1.6M-cycle streams).
        assert!(
            rep_b.cycles < 1_000_000,
            "{label}: window accounting leaked laggard time ({} cycles)",
            rep_b.cycles
        );
        assert!(!rep_b.exhausted, "{label}: core 0 supplies the full window");
        assert!(!stream.is_empty());
    }
}

/// The same skewed generator, sized so every stream ends before the
/// measured window fills: both drivers must agree on the exhausted flag
/// and on everything else.
#[test]
fn exhausted_streams_agree_between_drivers() {
    let mut cfg = SimConfig::hmc();
    cfg.policy = PolicyKind::Adaptive;
    cfg.warmup_requests = 200;
    cfg.measure_requests = 100_000; // far more than the generator holds
    cfg.runs = 1;
    let mut w = SkewedFinite::new(cfg.n_vaults);
    let (_, rep_s, rep_b) = diff_drivers(&cfg, &mut w, "skewed/exhausted");
    assert_eq!(rep_b, rep_s);
    assert!(rep_b.exhausted, "finite streams must report exhaustion");
}

/// Streams that run dry before the warmup boundary: the scalar driver's
/// warmed gate records nothing, so the batched frame machinery must not
/// leak pre-warm folds into the final stats.
#[test]
fn exhaustion_before_warmup_measures_nothing_in_both_drivers() {
    let mut cfg = SimConfig::hmc();
    cfg.policy = PolicyKind::Adaptive;
    cfg.warmup_requests = 50_000; // more than SkewedFinite ever supplies
    cfg.measure_requests = 10_000;
    cfg.runs = 1;
    let mut w = SkewedFinite::new(cfg.n_vaults);
    let (_, rep_s, rep_b) = diff_drivers(&cfg, &mut w, "skewed/pre-warm-exhausted");
    assert_eq!(rep_b, rep_s);
    assert!(rep_b.exhausted);
    assert_eq!(rep_b.stats.requests, 0, "nothing may count as measured");
    assert_eq!(rep_b.stats.l1_hits, 0);
    assert_eq!(rep_b.stats.latency.requests, 0);
}

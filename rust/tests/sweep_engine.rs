//! Sweep-engine integration tests: thread-count determinism, cache hits on
//! identical configs, and panic isolation — the contracts every figure
//! target and every future scaling PR builds on.

use dlpim::config::SimConfig;
use dlpim::coordinator::report::SimReport;
use dlpim::policy::PolicyKind;
use dlpim::sweep::{DiskCache, Sweep, SweepPoint};

fn tiny(policy: PolicyKind) -> SimConfig {
    let mut cfg = SimConfig::hmc();
    cfg.policy = policy;
    cfg.warmup_requests = 200;
    cfg.measure_requests = 1_500;
    cfg.epoch_cycles = 5_000;
    cfg
}

/// 4 workloads x 2 configs — the acceptance-criteria matrix.
fn matrix_points() -> Vec<SweepPoint> {
    let cfgs = [tiny(PolicyKind::Never), tiny(PolicyKind::Always)];
    ["STRAdd", "STRCpy", "SPLRad", "HSJNPO"]
        .iter()
        .flat_map(|w| cfgs.iter().map(move |c| SweepPoint::new(*w, c.clone())))
        .collect()
}

/// Everything a report disagrees on when two runs diverge.
fn fingerprint(r: &SimReport) -> (u64, u64, u64, u64, u64) {
    let run = &r.runs[0];
    (
        run.cycles,
        run.stats.requests,
        run.stats.subscriptions,
        run.stats.traffic.total_bytes(),
        run.stats.latency.total(),
    )
}

#[test]
fn reports_identical_at_one_thread_and_many() {
    let serial = Sweep::new(matrix_points()).use_cache(false).threads(1).run();
    let parallel = Sweep::new(matrix_points()).use_cache(false).threads(8).run();
    assert_eq!(serial.len(), 8);
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.workload, b.workload, "submission order must be preserved");
        assert_eq!(
            fingerprint(a.report()),
            fingerprint(b.report()),
            "{} must not depend on thread count",
            a.workload
        );
    }
}

#[test]
fn identical_configs_hit_the_cache() {
    // A (workload, config) pair no other test in this binary uses, so the
    // first sweep is guaranteed to compute it. Disk persistence is off:
    // this test pins the *in-memory* level, and must not turn into a hit
    // on the second `cargo test` run via a leftover store entry
    // (tests/disk_cache.rs covers the persistent level hermetically).
    let mut cfg = tiny(PolicyKind::Never);
    cfg.seed = 0xCAFE_0001;
    let point = SweepPoint::new("STRSca", cfg);

    let first = Sweep::new(vec![point.clone()]).disk_cache(DiskCache::Off).run();
    assert!(!first[0].from_cache, "first run must compute");

    let second = Sweep::new(vec![point.clone()]).disk_cache(DiskCache::Off).run();
    assert!(second[0].from_cache, "identical config must reuse the cached report");
    assert_eq!(fingerprint(first[0].report()), fingerprint(second[0].report()));

    // Any config difference must miss.
    let mut other_cfg = point.cfg.clone();
    other_cfg.seed ^= 1;
    let third = Sweep::new(vec![SweepPoint::new("STRSca", other_cfg)])
        .disk_cache(DiskCache::Off)
        .run();
    assert!(!third[0].from_cache, "a different seed is a different point");
}

#[test]
fn panicking_job_leaves_other_reports_intact() {
    let mut points = matrix_points();
    points.insert(1, SweepPoint::new("NOPE", tiny(PolicyKind::Never)));
    let out = Sweep::new(points).use_cache(false).threads(4).run();
    assert_eq!(out.len(), 9);

    let poisoned = out[1].result.as_ref().unwrap_err();
    assert!(poisoned.contains("unknown workload"), "got {poisoned:?}");

    for (i, outcome) in out.iter().enumerate() {
        if i == 1 {
            continue;
        }
        let report = outcome.report();
        assert!(
            report.runs[0].stats.requests >= 1_500,
            "job {i} ({}) must have completed its measured window",
            outcome.workload
        );
    }
}

#[test]
fn paired_configs_share_seeds_across_policies() {
    // The paired methodology behind every speedup figure: the baseline and
    // the policy run of one workload must simulate the same stream.
    let base = SweepPoint::new("SPLRad", tiny(PolicyKind::Never));
    let always = SweepPoint::new("SPLRad", tiny(PolicyKind::Always));
    let other = SweepPoint::new("HSJNPO", tiny(PolicyKind::Never));
    assert_eq!(base.job_cfg().seed, always.job_cfg().seed);
    assert_ne!(base.job_cfg().seed, other.job_cfg().seed);
}

#[test]
fn run_matrix_routes_through_the_engine() {
    let cfgs = [tiny(PolicyKind::Never), tiny(PolicyKind::Always)];
    let out = dlpim::figures::run_matrix(&["STRAdd", "SPLRad"], &cfgs);
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].len(), 2);
    assert_eq!(out[0][0].workload, "STRAdd");
    assert_eq!(out[1][1].workload, "SPLRad");
    assert_eq!(out[1][1].policy, "always");
}

//! Generator-determinism property tests: all 31 Table III generators must
//! replay identically under `reset(seed)` — same seed, same interleave of
//! `next_op` calls, identical streams; different seed, different streams.
//!
//! This pins the contract trace recording depends on: a `.dlpt` file is
//! only a faithful stand-in for its generator because the generator
//! itself is a pure function of `(seed, call sequence)`.

use dlpim::config::SimConfig;
use dlpim::proptest_lite::{gen, Runner};
use dlpim::workloads::{catalog, Op, Workload};

/// Collect the first `per_core` ops of every core, round-robin — the same
/// interleave the recording tee sees from the driver at time zero.
fn sample(w: &mut dyn Workload, n_cores: u16, per_core: usize) -> Vec<(u16, Option<Op>)> {
    let mut out = Vec::with_capacity(n_cores as usize * per_core);
    for round in 0..per_core {
        for c in 0..n_cores {
            // Vary the visit order across rounds so cross-core state (if a
            // generator ever grew any) could not hide behind one fixed
            // interleave.
            let core = (c + round as u16) % n_cores;
            out.push((core, w.next_op(core)));
        }
    }
    out
}

#[test]
fn all_generators_replay_identically_under_same_seed() {
    let cfg = SimConfig::hmc();
    Runner::new(0x7ace_5eed).cases(6).run("same seed -> identical stream", |r| {
        let seed = r.next_u64();
        for name in catalog::ALL_NAMES {
            let mut a = catalog::build(name, &cfg).unwrap();
            let mut b = catalog::build(name, &cfg).unwrap();
            a.reset(seed);
            b.reset(seed);
            let sa = sample(a.as_mut(), cfg.n_vaults, 64);
            let sb = sample(b.as_mut(), cfg.n_vaults, 64);
            if sa != sb {
                return Err(format!("{name} diverged under seed {seed:#x}"));
            }
        }
        Ok(())
    });
}

#[test]
fn all_generators_reset_reproduces_from_the_top() {
    // reset() must rewind mid-stream state completely: consume a prefix,
    // reset with the same seed, and the stream must restart identically.
    let cfg = SimConfig::hmc();
    Runner::new(0xbead_cafe).cases(6).run("reset rewinds", |r| {
        let seed = r.next_u64();
        let burn = gen::usize_in(r, 1, 500);
        for name in catalog::ALL_NAMES {
            let mut w = catalog::build(name, &cfg).unwrap();
            w.reset(seed);
            let fresh = sample(w.as_mut(), cfg.n_vaults, 32);
            for i in 0..burn {
                let _ = w.next_op((i % cfg.n_vaults as usize) as u16);
            }
            w.reset(seed);
            let again = sample(w.as_mut(), cfg.n_vaults, 32);
            if fresh != again {
                return Err(format!("{name} did not rewind under seed {seed:#x}"));
            }
        }
        Ok(())
    });
}

#[test]
fn all_generators_decorrelate_across_seeds() {
    let cfg = SimConfig::hmc();
    Runner::new(0xd1ff_5eed).cases(6).run("different seed -> different stream", |r| {
        let s1 = r.next_u64();
        let s2 = s1 ^ (1 + r.next_u64() % 0xffff);
        for name in catalog::ALL_NAMES {
            let mut a = catalog::build(name, &cfg).unwrap();
            let mut b = catalog::build(name, &cfg).unwrap();
            a.reset(s1);
            b.reset(s2);
            let sa = sample(a.as_mut(), cfg.n_vaults, 64);
            let sb = sample(b.as_mut(), cfg.n_vaults, 64);
            if sa == sb {
                return Err(format!(
                    "{name} produced identical streams for seeds {s1:#x} and {s2:#x}"
                ));
            }
        }
        Ok(())
    });
}

/// The determinism contract holds on the HBM core count too (8 cores).
#[test]
fn determinism_holds_on_hbm_geometry() {
    let cfg = SimConfig::hbm();
    for name in catalog::ALL_NAMES {
        let mut a = catalog::build(name, &cfg).unwrap();
        let mut b = catalog::build(name, &cfg).unwrap();
        a.reset(42);
        b.reset(42);
        assert_eq!(
            sample(a.as_mut(), cfg.n_vaults, 64),
            sample(b.as_mut(), cfg.n_vaults, 64),
            "{name} nondeterministic on 8 cores"
        );
    }
}

//! Sharded sweep execution: cross-worker integration pins.
//!
//! The claims of `sweep::shard` that matter to users are (1) a sweep's
//! artifact bytes are identical at any worker count, (2) that holds even
//! when a worker dies mid-claim — the survivors take over after the
//! lease TTL — and (3) a crashed worker's claim is recovered by the TTL
//! path, not by unwind cleanup. These tests drive the real
//! `run_spec_sharded` pipeline over real stores; the claim-file
//! mechanics have unit tests in `sweep::shard` itself.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use dlpim::exp::{self, render_json};
use dlpim::sweep::shard::ShardRunner;
use dlpim::sweep::store::DiskStore;

/// A 4-point grid (2 workloads x 2 policies), small enough to simulate
/// in milliseconds but wide enough that workers actually contend.
fn spec() -> exp::ExperimentSpec {
    exp::tomlspec::from_text(
        "name = shard-sweep\n\
         workloads = STRAdd,STRCpy\n\
         policies = never,always\n\
         warmup = 100\n\
         measure = 800\n\
         runs = 1\n",
    )
    .unwrap()
}

fn tmp_store(tag: &str) -> DiskStore {
    let dir = std::env::temp_dir()
        .join(format!("dlpim-shard-sweep-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    DiskStore::at(dir)
}

fn claim_files(store: &DiskStore) -> usize {
    std::fs::read_dir(store.dir())
        .map(|entries| {
            entries
                .filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().ends_with(".claim"))
                .count()
        })
        .unwrap_or(0)
}

#[test]
fn sharded_artifact_matches_plain_run_byte_for_byte() {
    let spec = spec();
    let plain = exp::run_spec(&spec).unwrap();
    let store = tmp_store("bytes");
    let runner = ShardRunner::new(store.clone(), "w1", Duration::from_secs(30));
    let (sharded, outcome) = exp::run_spec_sharded(&spec, &runner).unwrap();
    assert_eq!(outcome.simulated(), 4, "a fresh store simulates every point: {outcome:?}");
    assert_eq!(outcome.present, 0);
    assert_eq!(
        render_json(&spec, &plain).render(),
        render_json(&spec, &sharded).render(),
        "artifact bytes must not depend on the execution path"
    );
    std::fs::remove_dir_all(store.dir()).unwrap();
}

#[test]
fn three_workers_split_one_sweep_and_all_render_identically() {
    let spec = spec();
    let expected = render_json(&spec, &exp::run_spec(&spec).unwrap()).render();
    let store = tmp_store("three");
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..3)
            .map(|i| {
                let store = store.clone();
                let spec = &spec;
                let expected = &expected;
                s.spawn(move || {
                    let runner =
                        ShardRunner::new(store, format!("w{i}"), Duration::from_secs(30));
                    let (run, outcome) = exp::run_spec_sharded(spec, &runner).unwrap();
                    // Every worker accounts for the whole grid, however
                    // the points were split.
                    assert_eq!(outcome.simulated() + outcome.present, 4, "{outcome:?}");
                    // ... and every worker — not just the last — renders
                    // the same bytes as a plain single-process run.
                    assert_eq!(render_json(spec, &run).render(), *expected, "worker {i}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    assert_eq!(claim_files(&store), 0, "all claims released");
    std::fs::remove_dir_all(store.dir()).unwrap();
}

#[test]
fn a_dead_workers_claim_is_reclaimed_after_the_ttl() {
    let spec = spec();
    let store = tmp_store("crash");
    let ttl = Duration::from_millis(150);

    // Worker A dies (injected panic) right after acquiring its first
    // claim: the claim file must stay behind — recovery is the TTL
    // reclaim path, not unwind cleanup.
    let mut a = ShardRunner::new(store.clone(), "a", ttl);
    a.on_claim(|key| panic!("injected crash holding {key:016x}"));
    let crashed = catch_unwind(AssertUnwindSafe(|| exp::run_spec_sharded(&spec, &a)));
    assert!(crashed.is_err(), "the injected panic must escape the worker");
    drop(a); // stops A's heartbeat; the lease now ages toward the TTL
    assert_eq!(claim_files(&store), 1, "the crashed worker leaves its claim on disk");

    // Worker B completes the sweep: it spins on the held point until the
    // lease goes stale, reclaims it, and finishes the grid.
    let b = ShardRunner::new(store.clone(), "b", ttl);
    let (run, outcome) = exp::run_spec_sharded(&spec, &b).unwrap();
    assert!(outcome.reclaimed >= 1, "the abandoned point was taken over: {outcome:?}");
    assert_eq!(outcome.simulated() + outcome.present, 4, "{outcome:?}");
    assert_eq!(claim_files(&store), 0, "the reclaimed lease was released");

    // The crash changed nothing about the artifact.
    let plain = exp::run_spec(&spec).unwrap();
    assert_eq!(
        render_json(&spec, &run).render(),
        render_json(&spec, &plain).render(),
        "artifact bytes survive a mid-sweep worker crash"
    );
    std::fs::remove_dir_all(store.dir()).unwrap();
}

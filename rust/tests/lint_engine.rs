//! Integration tests for the `repro lint` engine (`dlpim::lint`).
//!
//! Two fixture trees under `tests/lint_fixtures/` act as miniature repo
//! roots: `violations/` seeds at least one finding per rule (D1–D5, A0),
//! `allowed/` carries the same hazards behind justified allows. The
//! acceptance test at the bottom runs the linter over the real repo root
//! — HEAD must lint clean.

use std::fs;
use std::path::{Path, PathBuf};

use dlpim::lint::{self, rules, scan};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures").join(name)
}

fn count(report: &lint::Report, rule: &str, allowed: bool) -> usize {
    report
        .findings
        .iter()
        .filter(|f| f.rule == rule && f.allowed.is_some() == allowed)
        .count()
}

#[test]
fn violations_fixture_fires_every_rule() {
    let report = lint::run(&fixture("violations")).expect("lint run");
    assert_eq!(report.files_scanned, 4, "3 sources + 1 integration test");
    assert_eq!(count(&report, "D1", false), 3, "{}", report.render_text());
    assert_eq!(count(&report, "D2", false), 1, "{}", report.render_text());
    assert_eq!(count(&report, "D3", false), 1, "{}", report.render_text());
    assert_eq!(count(&report, "D4", false), 1, "{}", report.render_text());
    assert_eq!(count(&report, "D5", false), 3, "{}", report.render_text());
    assert_eq!(count(&report, rules::A0_ID, false), 2, "{}", report.render_text());
    assert_eq!(report.allowed().count(), 0);
}

#[test]
fn violations_fixture_spans_are_accurate() {
    let report = lint::run(&fixture("violations")).expect("lint run");
    let has = |rule: &str, file: &str, line: usize| {
        report
            .findings
            .iter()
            .any(|f| f.rule == rule && f.file.ends_with(file) && f.line == line)
    };
    assert!(has("D1", "rust/src/sim/mod.rs", 3), "use HashMap line");
    assert!(has("D3", "rust/src/sim/mod.rs", 10), "Ordering::Relaxed line");
    assert!(has("D2", "rust/src/coordinator/agg.rs", 4), "Instant::now line");
    assert!(has("D4", "rust/src/coordinator/agg.rs", 5), "f64 line");
    assert!(has("D5", "rust/docs/ARCHITECTURE.md", 8), "missing test file row");
    assert!(has("D5", "rust/docs/ARCHITECTURE.md", 9), "row pinning no test");
    assert!(has("D5", "rust/tests/orphan_probe.rs", 1), "undocumented test");
}

#[test]
fn allowed_fixture_is_clean_and_keeps_justifications() {
    let report = lint::run(&fixture("allowed")).expect("lint run");
    assert_eq!(
        report.violations().count(),
        0,
        "allowed fixture must lint clean:\n{}",
        report.render_text()
    );
    assert_eq!(count(&report, "D1", true), 2);
    assert_eq!(count(&report, "D2", true), 1);
    assert_eq!(count(&report, "D3", true), 1);
    assert_eq!(count(&report, "D4", true), 2);
    assert_eq!(count(&report, "D5", true), 2, "markdown row + test-file allow");
    let justs: Vec<&str> =
        report.allowed().filter_map(|f| f.allowed.as_deref()).collect();
    assert!(justs.contains(&"drained in sorted order before any fold"));
    assert!(justs.contains(&"tooling row, not an invariant"), "{justs:?}");
    assert!(justs.contains(&"scratch fixture probe; intentionally undocumented"));
}

#[test]
fn findings_are_sorted_by_file_then_line() {
    let report = lint::run(&fixture("violations")).expect("lint run");
    let keys: Vec<(&str, usize)> =
        report.findings.iter().map(|f| (f.file.as_str(), f.line)).collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted);
}

#[test]
fn render_text_is_one_line_per_violation_plus_summary() {
    let report = lint::run(&fixture("violations")).expect("lint run");
    let text = report.render_text();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), report.violations().count() + 1);
    for (line, f) in lines.iter().zip(report.violations()) {
        assert!(
            line.starts_with(&format!("{}:{}: {}", f.file, f.line, f.rule)),
            "bad line: {line}"
        );
    }
    assert!(lines.last().expect("summary").contains("violation(s)"));

    let clean = lint::run(&fixture("allowed")).expect("lint run");
    assert!(clean.render_text().contains("lint: clean"));
    assert!(clean.render_text().contains("allowed exception(s)"));
}

#[test]
fn json_report_carries_schema_rules_and_justifications() {
    let clean = lint::run(&fixture("allowed")).expect("lint run");
    let json = clean.to_json().render();
    assert!(json.contains("repro-lint-v1"), "{json}");
    for id in ["D1", "D2", "D3", "D4", "D5", "A0"] {
        assert!(json.contains(&format!("\"{id}\"")), "rule {id} missing: {json}");
    }
    assert!(json.contains("drained in sorted order before any fold"), "{json}");

    let red = lint::run(&fixture("violations")).expect("lint run");
    let json = red.to_json().render();
    assert!(json.contains("\"violations\":11"), "{json}");
    assert!(json.contains("\"allowed\":0"), "{json}");
}

#[test]
fn tokenizer_skips_strings_comments_and_test_code() {
    // A hazard token inside a string literal is data, not code.
    let f = scan::scan_source(
        "rust/src/sim/mod.rs",
        r#"pub fn f() -> &'static str { "HashMap and Instant::now stay data" }"#,
    );
    assert!(rules::check_file(&f).is_empty());

    // ... inside a `//` comment likewise.
    let f = scan::scan_source(
        "rust/src/sim/mod.rs",
        "pub fn f() {} // HashMap::new() would break determinism here\n",
    );
    assert!(rules::check_file(&f).is_empty());

    // ... and inside a #[cfg(test)] block.
    let f = scan::scan_source(
        "rust/src/sim/mod.rs",
        concat!(
            "pub fn real() {}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    use std::collections::HashMap;\n",
            "    fn t() { let _ = HashMap::<u8, u8>::new(); }\n",
            "}\n",
        ),
    );
    assert!(rules::check_file(&f).is_empty());
}

#[test]
fn allow_without_justification_is_itself_an_error() {
    let f = scan::scan_source(
        "rust/src/sim/mod.rs",
        "use std::collections::HashMap; // lint:allow(D1)\n",
    );
    let findings = rules::check_file(&f);
    assert!(
        findings.iter().any(|f| f.rule == "D1" && f.allowed.is_none()),
        "a bare allow must not shield the finding"
    );
    assert!(
        findings
            .iter()
            .any(|f| f.rule == rules::A0_ID && f.message.contains("justification")),
        "the bare allow is reported under A0"
    );
}

fn copy_tree(src: &Path, dst: &Path) {
    fs::create_dir_all(dst).expect("create fixture copy dir");
    for entry in fs::read_dir(src).expect("read fixture dir") {
        let entry = entry.expect("fixture dir entry");
        let to = dst.join(entry.file_name());
        if entry.path().is_dir() {
            copy_tree(&entry.path(), &to);
        } else {
            fs::copy(entry.path(), &to).expect("copy fixture file");
        }
    }
}

#[test]
fn fix_allow_inserts_placeholders_but_keeps_the_tree_red() {
    let work = std::env::temp_dir().join("dlpim_lint_fix_allow_fixture");
    if work.exists() {
        fs::remove_dir_all(&work).expect("clear previous fixture copy");
    }
    copy_tree(&fixture("violations"), &work);

    let before = lint::run(&work).expect("lint run");
    let rewritten = lint::fix_allow(&work, &before).expect("fix-allow");
    assert_eq!(rewritten, 3, "sim/mod.rs, coordinator/agg.rs, orphan_probe.rs");

    let after = lint::run(&work).expect("lint re-run");
    for f in after.violations() {
        assert!(
            f.rule == rules::A0_ID || f.file.ends_with(".md"),
            "D1-D4 must now be shielded by placeholders; still raw: {} {}:{}",
            f.rule,
            f.file,
            f.line
        );
    }
    assert!(
        after
            .violations()
            .any(|f| f.rule == rules::A0_ID && f.message.contains("placeholder")),
        "the TODO placeholders keep the tree red via A0"
    );
    assert!(
        after.violations().count() > 0,
        "fix-allow must not silently green the tree"
    );
    fs::remove_dir_all(&work).expect("clean up fixture copy");
}

#[test]
fn repo_at_head_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf();
    let report = lint::run(&root).expect("lint run over the real repo");
    assert!(report.files_scanned > 30, "scanned {}", report.files_scanned);
    assert_eq!(
        report.violations().count(),
        0,
        "HEAD must lint clean:\n{}",
        report.render_text()
    );
}

//! Integration tests: the paper's headline *shapes* must hold on quick-
//! scale runs — who wins, who loses, who is indifferent, and how the two
//! memory technologies compare.

use dlpim::config::{MemKind, SimConfig};
use dlpim::coordinator::driver::simulate;
use dlpim::coordinator::report::SimReport;
use dlpim::policy::PolicyKind;
use dlpim::workloads::catalog;

fn run(mem: MemKind, policy: PolicyKind, workload: &str) -> SimReport {
    let mut cfg = match mem {
        MemKind::Hmc => SimConfig::hmc(),
        MemKind::Hbm => SimConfig::hbm(),
    }
    .quick();
    cfg.policy = policy;
    simulate(&cfg, catalog::build(workload, &cfg).unwrap())
}

fn speedup(mem: MemKind, policy: PolicyKind, workload: &str) -> f64 {
    let base = run(mem, PolicyKind::Never, workload);
    run(mem, policy, workload).speedup_vs(&base)
}

// ---- Fig 9: always-subscribe winners and losers ----

#[test]
fn splrad_wins_big_under_always_subscribe() {
    // Paper: +105% (2.05x). Shape: a large win.
    let s = speedup(MemKind::Hmc, PolicyKind::Always, "SPLRad");
    // Quick scale (cold tables) understates the win; default scale ~1.55.
    assert!(s > 1.3, "SPLRad always-subscribe speedup {s:.3} should be >> 1");
}

#[test]
fn phelinreg_wins_under_always_subscribe() {
    let s = speedup(MemKind::Hmc, PolicyKind::Always, "PHELinReg");
    assert!(s > 1.3, "PHELinReg speedup {s:.3}");
}

#[test]
fn gemm_family_is_hurt_by_always_subscribe() {
    // Paper: up to -17% for PLYgemm / PLY3mm.
    for w in ["PLYgemm", "PLY3mm"] {
        let s = speedup(MemKind::Hmc, PolicyKind::Always, w);
        assert!(s < 0.97, "{w} must lose under always-subscribe, got {s:.3}");
        assert!(s > 0.6, "{w} loss should be bounded, got {s:.3}");
    }
}

#[test]
fn streams_are_roughly_indifferent() {
    // Paper: speedup ~= 1.00 for STREAM.
    for w in ["STRAdd", "STRTriad"] {
        let s = speedup(MemKind::Hmc, PolicyKind::Always, w);
        assert!((0.85..1.25).contains(&s), "{w} always speedup {s:.3} should be ~1");
    }
}

#[test]
fn fft_transpose_has_no_reuse_to_exploit() {
    let rep = run(MemKind::Hmc, PolicyKind::Always, "SPLFftTra");
    let (l, r) = rep.reuse();
    // The residual ~0.2 is the L1 writeback of the row-write stream landing
    // on its own fill; no *demand* reuse exists.
    assert!(l + r < 0.4, "transpose reuse must be ~0, got {:.2}", l + r);
}

// ---- Fig 11: adaptive recovers the losers, keeps the winners ----

#[test]
fn adaptive_recovers_always_subscribe_losses() {
    for w in ["PLYgemm", "DRKYolo"] {
        let base = run(MemKind::Hmc, PolicyKind::Never, w);
        let always = run(MemKind::Hmc, PolicyKind::Always, w);
        let adaptive = run(MemKind::Hmc, PolicyKind::Adaptive, w);
        let s_al = always.speedup_vs(&base);
        let s_ad = adaptive.speedup_vs(&base);
        assert!(
            s_ad > s_al,
            "{w}: adaptive ({s_ad:.3}) must beat always ({s_al:.3})"
        );
    }
}

#[test]
fn adaptive_keeps_most_of_the_win_on_winners() {
    let base = run(MemKind::Hmc, PolicyKind::Never, "SPLRad");
    let always = run(MemKind::Hmc, PolicyKind::Always, "SPLRad");
    let adaptive = run(MemKind::Hmc, PolicyKind::Adaptive, "SPLRad");
    let s_al = always.speedup_vs(&base);
    let s_ad = adaptive.speedup_vs(&base);
    assert!(s_ad > 0.8 * s_al, "adaptive {s_ad:.3} vs always {s_al:.3}");
    assert!(s_ad > 1.25);
}

#[test]
fn adaptive_reduces_memory_latency_on_winners() {
    // Paper headline: -54% average latency per request on HMC.
    let base = run(MemKind::Hmc, PolicyKind::Never, "SPLRad");
    let adaptive = run(MemKind::Hmc, PolicyKind::Adaptive, "SPLRad");
    let impr = adaptive.latency_improvement_vs(&base);
    assert!(impr > 0.3, "latency improvement {:.1}% too small", impr * 100.0);
}

// ---- Fig 12: CoV flattening ----

#[test]
fn subscription_flattens_hot_vault_cov() {
    for w in ["PHELinReg", "SPLRad", "CHABsBez"] {
        let base = run(MemKind::Hmc, PolicyKind::Never, w);
        let adaptive = run(MemKind::Hmc, PolicyKind::Adaptive, w);
        assert!(base.cov() > 1.0, "{w} baseline CoV {:.2} should be high", base.cov());
        assert!(
            adaptive.cov() < base.cov(),
            "{w}: adaptive CoV {:.2} must drop below baseline {:.2}",
            adaptive.cov(),
            base.cov()
        );
    }
}

#[test]
fn balanced_workloads_have_low_cov() {
    for w in ["STRAdd", "HSJNPO"] {
        let rep = run(MemKind::Hmc, PolicyKind::Never, w);
        assert!(rep.cov() < 0.3, "{w} CoV {:.3} should be ~0", rep.cov());
    }
}

// ---- Fig 10 / selected set ----

#[test]
fn selected_workloads_have_reuse_and_streams_do_not() {
    let with = run(MemKind::Hmc, PolicyKind::Always, "PLYDoitgen");
    let (l, r) = with.reuse();
    assert!(l + r > 0.5, "doitgen reuse {:.2}", l + r);
    // Streams: the only "reuse" of a subscription is the single L1
    // writeback landing on the just-parked fill — bounded well below 1.
    let without = run(MemKind::Hmc, PolicyKind::Always, "STRCpy");
    let (l, r) = without.reuse();
    assert!(l + r < 0.7, "stream reuse {:.2}", l + r);
}

// ---- Figs 1/2: latency breakdown & HMC vs HBM ----

#[test]
fn baseline_has_substantial_remote_overhead() {
    // Paper: 53% HMC / 43% HBM average across workloads; per-workload
    // values vary, but a remote-heavy workload must show a large share.
    let rep = run(MemKind::Hmc, PolicyKind::Never, "HSJNPO");
    let (n, q, a) = rep.latency_fractions();
    assert!(n + q > 0.35, "remote overhead {:.2} too small", n + q);
    assert!(a > 0.15, "array share {a:.2} implausibly small");
}

#[test]
fn hot_vault_workloads_are_queue_dominated() {
    // Paper: high-CoV workloads attribute 70-80% of latency to queuing.
    let rep = run(MemKind::Hmc, PolicyKind::Never, "PHELinReg");
    let (_, q, _) = rep.latency_fractions();
    assert!(q > 0.5, "queue share {q:.2} should dominate");
}

#[test]
fn hbm_adaptive_also_improves_winners() {
    // Paper Fig 15: HBM gains are smaller than HMC's (8 uncongested
    // channels leave less queuing to recover); the winner must still gain
    // and its memory latency must drop.
    let base = run(MemKind::Hbm, PolicyKind::Never, "SPLRad");
    let adaptive = run(MemKind::Hbm, PolicyKind::Adaptive, "SPLRad");
    assert!(adaptive.speedup_vs(&base) > 1.0);
    assert!(adaptive.latency_improvement_vs(&base) > 0.05);
}

#[test]
fn hbm_network_share_is_smaller_than_hmc() {
    // 4x2 mesh vs 6x6 mesh: fewer hops, lower transfer share.
    let hmc = run(MemKind::Hmc, PolicyKind::Never, "HSJNPO");
    let hbm = run(MemKind::Hbm, PolicyKind::Never, "HSJNPO");
    let (n_hmc, _, _) = hmc.latency_fractions();
    let (n_hbm, _, _) = hbm.latency_fractions();
    assert!(
        n_hbm < n_hmc,
        "HBM network share {n_hbm:.3} must be below HMC {n_hmc:.3}"
    );
}

// ---- Fig 14: traffic ----

#[test]
fn always_subscribe_raises_traffic_adaptive_less() {
    let base = run(MemKind::Hmc, PolicyKind::Never, "PLYgemm");
    let always = run(MemKind::Hmc, PolicyKind::Always, "PLYgemm");
    let adaptive = run(MemKind::Hmc, PolicyKind::Adaptive, "PLYgemm");
    let (b, al, ad) =
        (base.bytes_per_cycle(), always.bytes_per_cycle(), adaptive.bytes_per_cycle());
    assert!(al > b * 0.95, "always traffic {al:.1} vs base {b:.1}");
    assert!(ad <= al, "adaptive traffic {ad:.1} must not exceed always {al:.1}");
}

#[test]
fn hot_vault_winner_moves_fewer_bytes_per_request() {
    // Paper: PHELinReg's bandwidth demand drops under DL-PIM (Fig 14).
    // Bytes *per cycle* can rise simply because execution got ~2x faster,
    // so compare bytes moved per demand request.
    let base = run(MemKind::Hmc, PolicyKind::Never, "PHELinReg");
    let adaptive = run(MemKind::Hmc, PolicyKind::Adaptive, "PHELinReg");
    let per_req = |r: &SimReport| {
        r.runs[0].stats.traffic.total_bytes() as f64 / r.runs[0].stats.requests as f64
    };
    // Our substrate keeps PHELinReg's per-request bytes ~flat (the win is
    // queuing/CoV); the paper reports a drop. Assert it does not *grow*.
    assert!(
        per_req(&adaptive) < per_req(&base) * 1.05,
        "adaptive must not move more bytes/request: {:.1} vs {:.1}",
        per_req(&adaptive),
        per_req(&base)
    );
}

// ---- Fig 16: table-size sensitivity ----

#[test]
fn bigger_tables_help_table_hungry_workloads() {
    let base = run(MemKind::Hmc, PolicyKind::Never, "PHELinReg");
    let mut small = dlpim::config::presets::hmc_adaptive_with_table_entries(1024).quick();
    small.policy = PolicyKind::Adaptive;
    let mut big = dlpim::config::presets::hmc_adaptive_with_table_entries(8192).quick();
    big.policy = PolicyKind::Adaptive;
    let s_small = simulate(&small, catalog::build("PHELinReg", &small).unwrap())
        .speedup_vs(&base);
    let s_big =
        simulate(&big, catalog::build("PHELinReg", &big).unwrap()).speedup_vs(&base);
    assert!(
        s_big > s_small,
        "8192-entry table ({s_big:.3}) must beat 1024 ({s_small:.3})"
    );
}

// ---- determinism across the whole stack ----

#[test]
fn full_simulation_is_deterministic() {
    let a = run(MemKind::Hmc, PolicyKind::Adaptive, "SPLRad");
    let b = run(MemKind::Hmc, PolicyKind::Adaptive, "SPLRad");
    assert_eq!(a.runs[0].cycles, b.runs[0].cycles);
    assert_eq!(a.runs[0].stats.subscriptions, b.runs[0].stats.subscriptions);
    assert_eq!(a.runs[0].stats.traffic, b.runs[0].stats.traffic);
}

#[test]
fn different_seeds_differ_but_agree_qualitatively() {
    let mut cfg = SimConfig::hmc().quick();
    cfg.policy = PolicyKind::Always;
    cfg.seed = 1;
    let a = simulate(&cfg, catalog::build("SPLRad", &cfg).unwrap());
    cfg.seed = 2;
    let b = simulate(&cfg, catalog::build("SPLRad", &cfg).unwrap());
    assert_ne!(a.runs[0].cycles, b.runs[0].cycles, "seeds must matter");
    let ratio = a.cycles() / b.cycles();
    assert!((0.7..1.4).contains(&ratio), "seed noise too large: {ratio:.2}");
}

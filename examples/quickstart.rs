//! Quickstart: simulate one workload under baseline and DL-PIM adaptive,
//! print the paper's headline metrics.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dlpim::config::SimConfig;
use dlpim::coordinator::driver::simulate;
use dlpim::policy::PolicyKind;
use dlpim::workloads::catalog;

fn main() {
    // Radix sort: the paper's biggest DL-PIM winner (+105% in Fig 9).
    let workload = "SPLRad";

    let mut base_cfg = SimConfig::hmc().quick();
    base_cfg.policy = PolicyKind::Never;
    let mut dl_cfg = base_cfg.clone();
    dl_cfg.policy = PolicyKind::Adaptive;

    println!("simulating {workload} on a 6x6 HMC mesh (32 vaults)...\n");

    let base = simulate(&base_cfg, catalog::build(workload, &base_cfg).unwrap());
    let dlpim = simulate(&dl_cfg, catalog::build(workload, &dl_cfg).unwrap());

    let (bn, bq, ba) = base.latency_fractions();
    println!("baseline   : {:>9.0} cycles | {:6.1} cyc/req | net {:.0}% queue {:.0}% array {:.0}% | CoV {:.2}",
        base.cycles(), base.avg_latency(), bn * 100.0, bq * 100.0, ba * 100.0, base.cov());
    let (dn, dq, da) = dlpim.latency_fractions();
    println!("dl-pim     : {:>9.0} cycles | {:6.1} cyc/req | net {:.0}% queue {:.0}% array {:.0}% | CoV {:.2}",
        dlpim.cycles(), dlpim.avg_latency(), dn * 100.0, dq * 100.0, da * 100.0, dlpim.cov());
    println!();
    println!("speedup            : {:.2}x", dlpim.speedup_vs(&base));
    println!("latency improvement: {:.1}%", dlpim.latency_improvement_vs(&base) * 100.0);
    println!("local accesses     : {:.1}% (baseline {:.1}%)",
        dlpim.local_fraction() * 100.0, base.local_fraction() * 100.0);
    let r = &dlpim.runs[0];
    println!(
        "protocol activity  : {} subscriptions, {} resubscriptions, {} unsubscriptions",
        r.stats.subscriptions, r.stats.resubscriptions, r.stats.unsubscriptions
    );
}

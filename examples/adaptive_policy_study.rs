//! Policy study: how each adaptive mechanism of §III-D behaves on a
//! DL-PIM winner (SPLRad), a loser (PLYgemm), and a neutral streaming
//! workload (STRTriad) — including the epoch-by-epoch decision trace.
//!
//! ```bash
//! cargo run --release --example adaptive_policy_study
//! ```

use dlpim::config::SimConfig;
use dlpim::coordinator::driver::simulate;
use dlpim::policy::PolicyKind;
use dlpim::workloads::catalog;

fn main() {
    let workloads = ["SPLRad", "PLYgemm", "STRTriad"];
    let policies = [
        PolicyKind::Always,
        PolicyKind::AdaptiveHops,
        PolicyKind::AdaptiveLatency,
        PolicyKind::Adaptive,
    ];

    for wl in workloads {
        let mut base_cfg = SimConfig::hmc().quick();
        base_cfg.policy = PolicyKind::Never;
        let base = simulate(&base_cfg, catalog::build(wl, &base_cfg).unwrap());
        println!("== {wl} (baseline {:.0} cycles, {:.1} cyc/req)", base.cycles(), base.avg_latency());

        for p in policies {
            let mut cfg = base_cfg.clone();
            cfg.policy = p;
            let rep = simulate(&cfg, catalog::build(wl, &cfg).unwrap());
            let decisions = &rep.runs[0].decisions;
            let on_epochs = decisions.iter().filter(|d| d.enabled).count();
            println!(
                "  {:<17} speedup {:.3} | latency impr {:+5.1}% | epochs on/total {}/{}",
                p.as_str(),
                rep.speedup_vs(&base),
                rep.latency_improvement_vs(&base) * 100.0,
                on_epochs,
                decisions.len(),
            );
            if p == PolicyKind::Adaptive && !decisions.is_empty() {
                let trace: Vec<&str> =
                    decisions.iter().take(12).map(|d| if d.enabled { "on" } else { "off" }).collect();
                println!("                    decision trace: {}", trace.join(" -> "));
            }
        }
        println!();
    }
    println!("expected shape: SPLRad gains under every subscribe policy; PLYgemm is");
    println!("hurt by always-subscribe and recovered by the adaptive policies;");
    println!("STRTriad is indifferent (no post-L1 reuse to exploit).");
}

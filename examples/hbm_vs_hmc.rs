//! HMC vs HBM: the paper's two 3-D stacked memories side by side —
//! network size drives remote overhead (53% vs 43%) and with it DL-PIM's
//! headroom (6% vs 3% average speedup).
//!
//! ```bash
//! cargo run --release --example hbm_vs_hmc
//! ```

use dlpim::config::{MemKind, SimConfig};
use dlpim::coordinator::driver::simulate;
use dlpim::policy::PolicyKind;
use dlpim::workloads::catalog;

fn main() {
    let workloads = ["PHELinReg", "SPLRad", "PLYcon2d", "HSJNPO", "STRAdd"];

    println!(
        "{:<12} | {:^31} | {:^31}",
        "workload", "HMC 6x6 (32 vaults)", "HBM 4x2 (8 channels)"
    );
    println!(
        "{:<12} | {:>9} {:>10} {:>9} | {:>9} {:>10} {:>9}",
        "", "overhead", "lat impr", "speedup", "overhead", "lat impr", "speedup"
    );

    for wl in workloads {
        let mut row = format!("{wl:<12}");
        for mem in [MemKind::Hmc, MemKind::Hbm] {
            let mut base_cfg = match mem {
                MemKind::Hmc => SimConfig::hmc(),
                MemKind::Hbm => SimConfig::hbm(),
            }
            .quick();
            base_cfg.policy = PolicyKind::Never;
            let mut ad_cfg = base_cfg.clone();
            ad_cfg.policy = PolicyKind::Adaptive;

            let base = simulate(&base_cfg, catalog::build(wl, &base_cfg).unwrap());
            let adap = simulate(&ad_cfg, catalog::build(wl, &ad_cfg).unwrap());
            let (n, q, _) = base.latency_fractions();
            row.push_str(&format!(
                " | {:>8.1}% {:>9.1}% {:>9.3}",
                (n + q) * 100.0,
                adap.latency_improvement_vs(&base) * 100.0,
                adap.speedup_vs(&base)
            ));
        }
        println!("{row}");
    }
    println!();
    println!("paper shape: HMC's bigger mesh means more remote overhead, hence more");
    println!("for DL-PIM to recover (54% vs 50% latency; 6% vs 3% speedup).");
}

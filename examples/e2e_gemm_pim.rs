//! End-to-end driver: the full three-layer stack on one real workload.
//!
//! A 256x256 blocked GEMM (the compute kernel of DRKYolo / PLYgemm) is
//! executed tile-by-tile through the **AOT-compiled Pallas kernel** (L1,
//! `artifacts/gemm_tile.hlo.txt`, built by `make artifacts` and run here
//! via the PJRT CPU client — no Python on this path), while the **L3
//! simulator** replays the *exact* memory trace of the same tiling under
//! baseline and DL-PIM adaptive policies. Numerics are verified against a
//! Rust reference; the simulator reports the paper's headline metrics for
//! the traffic the computation actually generated.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_gemm_pim
//! ```

use dlpim::config::SimConfig;
use dlpim::coordinator::driver::simulate;
use dlpim::policy::PolicyKind;
use dlpim::rng::Rng;
use dlpim::runtime::ArtifactStore;
use dlpim::workloads::{Op, Workload};
use dlpim::CoreId;

const N: usize = 256; // matrix dimension
const T: usize = 64; // tile dimension (matches the Pallas kernel)
const TILES: usize = N / T;

/// Replay a recorded per-core trace through the simulator.
struct TraceWorkload {
    ops: Vec<Vec<Op>>,
    idx: Vec<usize>,
}

impl Workload for TraceWorkload {
    fn name(&self) -> &'static str {
        "E2E-GEMM"
    }

    fn next_op(&mut self, core: CoreId) -> Option<Op> {
        let c = core as usize;
        let ops = &self.ops[c];
        if ops.is_empty() {
            return None;
        }
        // Loop the trace so warmup + measurement always have work.
        let op = ops[self.idx[c] % ops.len()];
        self.idx[c] += 1;
        Some(op)
    }

    fn reset(&mut self, _seed: u64) {
        self.idx.iter_mut().for_each(|i| *i = 0);
    }
}

/// Byte address of element (r, c) of matrix `m` (0 = A, 1 = B, 2 = C).
fn elem_addr(m: u64, r: usize, c: usize) -> u64 {
    let base = 1 + m * (64 << 20);
    base + (r * N + c) as u64 * 4
}

/// Record the block-level trace of one tile-multiply executed by `core`:
/// read the A and B tiles, accumulate into the C tile.
fn trace_tile(ops: &mut Vec<Op>, ti: usize, tj: usize, tk: usize) {
    for m_r_c_w in [
        (0u64, ti * T, tk * T, false), // A[ti, tk]
        (1, tk * T, tj * T, false),    // B[tk, tj]
        (2, ti * T, tj * T, true),     // C[ti, tj] (read-modify-write)
    ] {
        let (m, r0, c0, write) = m_r_c_w;
        for r in (r0..r0 + T).step_by(1) {
            // 64 f32 per row = 256 B = 4 blocks of 64 B.
            for cb in (c0..c0 + T).step_by(16) {
                ops.push(Op { addr: elem_addr(m, r, cb), write, gap: 4 });
            }
        }
    }
}

fn main() -> dlpim::error::Result<()> {
    println!("== L1/L2: AOT Pallas GEMM tile kernel via PJRT ==");
    let mut store = ArtifactStore::discover()?;
    println!("platform: {}", store.platform());
    let mut rng = Rng::new(0xE2E);
    let a: Vec<f32> = (0..N * N).map(|_| rng.f64() as f32 - 0.5).collect();
    let b: Vec<f32> = (0..N * N).map(|_| rng.f64() as f32 - 0.5).collect();

    // Reference result (Rust, naive blocked).
    let mut c_ref = vec![0f32; N * N];
    for i in 0..N {
        for k in 0..N {
            let aik = a[i * N + k];
            for j in 0..N {
                c_ref[i * N + j] += aik * b[k * N + j];
            }
        }
    }

    // Tile-by-tile through the AOT kernel, accumulating on the Rust side —
    // exactly the dataflow whose memory trace the simulator replays below.
    let exe = store.get("gemm_tile")?;
    let mut c = vec![0f32; N * N];
    let mut tile_a = vec![0f32; T * T];
    let mut tile_b = vec![0f32; T * T];
    let t0 = std::time::Instant::now();
    let mut kernel_calls = 0u32;
    for ti in 0..TILES {
        for tj in 0..TILES {
            for tk in 0..TILES {
                for r in 0..T {
                    for cc in 0..T {
                        tile_a[r * T + cc] = a[(ti * T + r) * N + tk * T + cc];
                        tile_b[r * T + cc] = b[(tk * T + r) * N + tj * T + cc];
                    }
                }
                let out = exe.run_f32(&[(&tile_a, &[T, T]), (&tile_b, &[T, T])])?;
                kernel_calls += 1;
                for r in 0..T {
                    for cc in 0..T {
                        c[(ti * T + r) * N + tj * T + cc] += out[0][r * T + cc];
                    }
                }
            }
        }
    }
    let max_err = c
        .iter()
        .zip(&c_ref)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    println!(
        "{kernel_calls} kernel calls in {:.2}s | max |err| vs Rust reference = {max_err:.2e}",
        t0.elapsed().as_secs_f64()
    );
    assert!(max_err < 1e-3, "PJRT numerics diverged");

    println!("\n== L3: simulating the same tiling's memory traffic ==");
    // Tiles are distributed over cores round-robin by (ti, tj), the same
    // schedule a PIM runtime would use; each core's trace is the block
    // stream of its tile-multiplies.
    let build_trace = |n_cores: u16| -> TraceWorkload {
        let mut ops: Vec<Vec<Op>> = vec![Vec::new(); n_cores as usize];
        let mut core = 0usize;
        for ti in 0..TILES {
            for tj in 0..TILES {
                for tk in 0..TILES {
                    trace_tile(&mut ops[core % n_cores as usize], ti, tj, tk);
                }
                core += 1;
            }
        }
        let idx = vec![0; n_cores as usize];
        TraceWorkload { ops, idx }
    };

    let mut base_cfg = SimConfig::hmc().quick();
    base_cfg.policy = PolicyKind::Never;
    let mut ad_cfg = base_cfg.clone();
    ad_cfg.policy = PolicyKind::Adaptive;

    let base = simulate(&base_cfg, Box::new(build_trace(base_cfg.n_vaults)));
    let adap = simulate(&ad_cfg, Box::new(build_trace(ad_cfg.n_vaults)));

    let (n, q, ar) = base.latency_fractions();
    println!(
        "baseline : {:>9.0} cycles | {:5.1} cyc/req | net {:.0}% queue {:.0}% array {:.0}%",
        base.cycles(),
        base.avg_latency(),
        n * 100.0,
        q * 100.0,
        ar * 100.0
    );
    println!(
        "dl-pim   : {:>9.0} cycles | {:5.1} cyc/req | local {:.1}% of requests",
        adap.cycles(),
        adap.avg_latency(),
        adap.local_fraction() * 100.0
    );
    println!("speedup             : {:.3}x", adap.speedup_vs(&base));
    println!(
        "latency improvement : {:.1}%",
        adap.latency_improvement_vs(&base) * 100.0
    );
    println!("\nall three layers composed: Pallas kernel (AOT) -> PJRT (Rust) -> DL-PIM sim.");
    Ok(())
}

"""Layer-2 model shapes + the AOT lowering contract: every artifact in the
manifest lowers to parseable HLO text with the expected entry signature."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import aot, model
from compile.kernels import ref


class TestModels:
    def test_gemm_model_matches_ref(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((256, 256)), dtype=jnp.float32)
        b = jnp.asarray(rng.standard_normal((256, 256)), dtype=jnp.float32)
        (out,) = model.gemm_model(a, b)
        np.testing.assert_allclose(out, ref.gemm_ref(a, b), rtol=1e-4, atol=1e-4)

    def test_linreg_model_recovers_line(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal(1 << 16), dtype=jnp.float32)
        y = 2.5 * x + 1.25
        slope, intercept = model.linreg_model(x, y)
        assert abs(float(slope) - 2.5) < 1e-3
        assert abs(float(intercept) - 1.25) < 1e-3

    @settings(deadline=None, max_examples=6)
    @given(
        slope=st.floats(-5, 5),
        intercept=st.floats(-5, 5),
        seed=st.integers(0, 2**16),
    )
    def test_linreg_sweep(self, slope, intercept, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(1 << 14), dtype=jnp.float32)
        y = slope * x + intercept
        s, i = model.linreg_model(x, y)
        assert abs(float(s) - slope) < 5e-3
        assert abs(float(i) - intercept) < 5e-3

    def test_triad_model(self):
        b = jnp.ones(1 << 16, dtype=jnp.float32)
        c = jnp.full(1 << 16, 2.0, dtype=jnp.float32)
        (out,) = model.triad_model(b, c)
        np.testing.assert_allclose(out, 7.0, rtol=1e-6)


class TestAot:
    def test_manifest_complete(self):
        # The Makefile's artifact list must exactly match the manifest.
        assert set(model.ARTIFACTS) == {
            "gemm",
            "gemm_tile",
            "stencil2d",
            "stream_triad",
            "linreg",
        }

    def test_every_artifact_lowers_to_hlo_text(self):
        for name in model.ARTIFACTS:
            text = aot.lower_one(name)
            assert text.startswith("HloModule"), f"{name}: not HLO text"
            assert "ENTRY" in text, f"{name}: no entry computation"

    def test_gemm_tile_signature(self):
        text = aot.lower_one("gemm_tile")
        # Two f32[64,64] parameters, tuple output.
        assert text.count("f32[64,64]") >= 3
        assert "(f32[64,64])" in text or "tuple" in text.lower()

    def test_hlo_text_is_deterministic(self):
        a = aot.lower_one("stream_triad")
        b = aot.lower_one("stream_triad")
        assert a == b

    def test_writes_files(self, tmp_path):
        import subprocess
        import sys

        out = tmp_path / "arts"
        r = subprocess.run(
            [
                sys.executable,
                "-m",
                "compile.aot",
                "--out",
                str(out),
                "--only",
                "gemm_tile",
            ],
            capture_output=True,
            text=True,
            cwd=str(jax.numpy.__file__ and __import__("pathlib").Path(__file__).parent.parent),
        )
        assert r.returncode == 0, r.stderr
        assert (out / "gemm_tile.hlo.txt").exists()

"""Layer-1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes and value distributions; fixed cases pin the
shapes the AOT artifacts use.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import gemm, ref, stencil2d, stream

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


# ---------------------------------------------------------------- gemm


class TestGemm:
    def test_artifact_shape(self):
        rng = np.random.default_rng(0)
        a, b = rand(rng, 256, 256), rand(rng, 256, 256)
        np.testing.assert_allclose(
            gemm.gemm(a, b), ref.gemm_ref(a, b), rtol=1e-4, atol=1e-4
        )

    def test_tile_kernel(self):
        rng = np.random.default_rng(1)
        a, b = rand(rng, 64, 64), rand(rng, 64, 64)
        np.testing.assert_allclose(
            gemm.gemm_tile(a, b), ref.gemm_ref(a, b), rtol=1e-4, atol=1e-4
        )

    @settings(deadline=None, max_examples=12)
    @given(
        mi=st.integers(1, 3),
        ni=st.integers(1, 3),
        k=st.sampled_from([32, 64, 96]),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, mi, ni, k, seed):
        rng = np.random.default_rng(seed)
        a = rand(rng, mi * 64, k)
        b = rand(rng, k, ni * 64)
        np.testing.assert_allclose(
            gemm.gemm(a, b), ref.gemm_ref(a, b), rtol=1e-4, atol=1e-4
        )

    @settings(deadline=None, max_examples=8)
    @given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**16))
    def test_value_scale_sweep(self, scale, seed):
        rng = np.random.default_rng(seed)
        a = rand(rng, 64, 64) * scale
        b = rand(rng, 64, 64)
        np.testing.assert_allclose(
            gemm.gemm(a, b), ref.gemm_ref(a, b), rtol=1e-3, atol=1e-3 * scale
        )

    def test_identity(self):
        eye = jnp.eye(64, dtype=jnp.float32)
        rng = np.random.default_rng(2)
        a = rand(rng, 64, 64)
        np.testing.assert_allclose(gemm.gemm(a, eye), a, rtol=1e-5, atol=1e-5)

    def test_rejects_ragged(self):
        rng = np.random.default_rng(3)
        with pytest.raises(AssertionError):
            gemm.gemm(rand(rng, 65, 64), rand(rng, 64, 64))


# ------------------------------------------------------------- stencil


class TestStencil:
    def test_artifact_shape(self):
        rng = np.random.default_rng(4)
        x = rand(rng, 256, 256)
        np.testing.assert_allclose(
            stencil2d.stencil5(x), ref.stencil5_ref(x), rtol=1e-5, atol=1e-5
        )

    @settings(deadline=None, max_examples=10)
    @given(
        hb=st.integers(1, 4),
        w=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, hb, w, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, hb * 32, w)
        np.testing.assert_allclose(
            stencil2d.stencil5(x), ref.stencil5_ref(x), rtol=1e-5, atol=1e-5
        )

    def test_constant_field_interior(self):
        # Interior of a constant field: 0.5 + 4*0.125 = 1.0 x the value.
        x = jnp.full((96, 96), 2.0, dtype=jnp.float32)
        y = stencil2d.stencil5(x)
        np.testing.assert_allclose(y[1:-1, 1:-1], 2.0, rtol=1e-6)

    def test_zero_boundary(self):
        x = jnp.ones((32, 32), dtype=jnp.float32)
        y = stencil2d.stencil5(x)
        # Corner sees 2 zero-padded neighbours: 0.5 + 2*0.125 = 0.75.
        assert abs(float(y[0, 0]) - 0.75) < 1e-6

    def test_coefficients(self):
        rng = np.random.default_rng(5)
        x = rand(rng, 64, 64)
        np.testing.assert_allclose(
            stencil2d.stencil5(x, c_center=1.0, c_neigh=0.0),
            x,
            rtol=1e-6,
            atol=1e-6,
        )


# --------------------------------------------------------------- triad


class TestTriad:
    def test_artifact_shape(self):
        rng = np.random.default_rng(6)
        b, c = rand(rng, 1 << 16), rand(rng, 1 << 16)
        np.testing.assert_allclose(
            stream.triad(b, c, 3.0), ref.triad_ref(b, c, 3.0), rtol=1e-5, atol=1e-5
        )

    @settings(deadline=None, max_examples=10)
    @given(
        nblocks=st.integers(1, 8),
        scalar=st.floats(-10, 10),
        seed=st.integers(0, 2**16),
    )
    def test_sweep(self, nblocks, scalar, seed):
        rng = np.random.default_rng(seed)
        n = nblocks * 1024
        b, c = rand(rng, n), rand(rng, n)
        np.testing.assert_allclose(
            stream.triad(b, c, scalar),
            ref.triad_ref(b, c, scalar),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_zero_scalar_is_copy(self):
        rng = np.random.default_rng(7)
        b, c = rand(rng, 2048), rand(rng, 2048)
        np.testing.assert_allclose(stream.triad(b, c, 0.0), b, rtol=1e-6)

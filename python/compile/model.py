"""Layer-2 JAX models: the compute graphs of the workload kernels the L3
simulator drives, built on the Layer-1 Pallas kernels.

Each model is a plain jitted-jax function over fixed example shapes (the
AOT contract); `aot.py` lowers every entry of `ARTIFACTS` to HLO text.
Outputs are tuples — the Rust side unwraps with `to_tuple`.

L2 optimization notes (DESIGN.md §Perf): every model is a single fused
HLO module — no Python-level loops survive lowering; the blocked GEMM's
accumulation is the kernel grid, not a scan, so XLA sees one fusion
region per tile; nothing is recomputed between tiles.
"""

import jax
import jax.numpy as jnp

from .kernels import gemm as gemm_k
from .kernels import stencil2d, stream


def gemm_model(a, b):
    """Full blocked GEMM (DRKYolo / PLYgemm compute): C = A @ B."""
    return (gemm_k.gemm(a, b),)


def gemm_tile_model(a, b):
    """One 64x64 tile multiply — the unit the Rust e2e driver executes
    per simulated tile-op."""
    return (gemm_k.gemm_tile(a, b),)


def stencil_model(x):
    """One 5-point relaxation sweep (PLYcon2d / SPLOcnpJac compute)."""
    return (stencil2d.stencil5(x),)


def triad_model(b, c):
    """STREAM triad with the canonical scalar (STRTriad compute)."""
    return (stream.triad(b, c, 3.0),)


def linreg_model(x, y):
    """Phoenix linear regression: the map-phase moment sums and the final
    fit, in one fused graph (pure L2 — its hot spot is the reduction, which
    XLA already emits optimally; no Pallas kernel needed)."""
    n = jnp.float32(x.shape[0])
    sx = jnp.sum(x)
    sy = jnp.sum(y)
    sxx = jnp.sum(x * x)
    sxy = jnp.sum(x * y)
    denom = n * sxx - sx * sx
    slope = (n * sxy - sx * sy) / denom
    intercept = (sy - slope * sx) / n
    return (slope, intercept)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


#: name -> (function, example argument shapes): the AOT manifest.
ARTIFACTS = {
    "gemm": (gemm_model, (_f32(256, 256), _f32(256, 256))),
    "gemm_tile": (gemm_tile_model, (_f32(64, 64), _f32(64, 64))),
    "stencil2d": (stencil_model, (_f32(256, 256),)),
    "stream_triad": (triad_model, (_f32(1 << 16), _f32(1 << 16))),
    "linreg": (linreg_model, (_f32(1 << 16), _f32(1 << 16))),
}

"""AOT pipeline: lower every Layer-2 model to HLO *text* artifacts.

HLO text — NOT ``lowered.compile()`` or proto ``.serialize()`` — is the
interchange format: jax >= 0.5 serializes HloModuleProto with 64-bit
instruction ids, which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts
Python runs ONCE here; the Rust binary is self-contained afterwards.
"""

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import ARTIFACTS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name: str) -> str:
    fn, args = ARTIFACTS[name]
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--only", nargs="*", default=None, help="subset of artifact names"
    )
    ns = ap.parse_args()
    out = pathlib.Path(ns.out)
    out.mkdir(parents=True, exist_ok=True)
    names = ns.only if ns.only else sorted(ARTIFACTS)
    for name in names:
        text = lower_one(name)
        path = out / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()

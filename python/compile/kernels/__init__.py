"""Layer-1 Pallas kernels (build-time only; lowered AOT into the HLO
artifacts the Rust runtime executes)."""

from . import gemm, ref, stencil2d, stream  # noqa: F401

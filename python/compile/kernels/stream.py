"""Layer-1 Pallas STREAM kernels (STRAdd/STRCpy/STRSca/STRTriad compute).

One-dimensional tiling: each grid step moves a `bs`-element block
HBM->VMEM, does one FMA, and writes back — deliberately the *zero-reuse*
end of the BlockSpec-as-subscription spectrum (see gemm.py): each block is
"subscribed" once and never touched again, exactly why STREAM sits at
speedup 1.00 in the paper's Fig 9.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _triad_kernel(s_ref, b_ref, c_ref, a_ref):
    a_ref[...] = b_ref[...] + s_ref[0] * c_ref[...]


@functools.partial(jax.jit, static_argnames=("bs",))
def triad(b, c, scalar, bs=1024):
    """a = b + scalar * c, tiled by `bs` elements."""
    (n,) = b.shape
    assert n % bs == 0, "length must tile by bs"
    s = jnp.asarray(scalar, dtype=jnp.float32).reshape(1)
    return pl.pallas_call(
        _triad_kernel,
        grid=(n // bs,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(s, b, c)

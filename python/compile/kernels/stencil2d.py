"""Layer-1 Pallas 5-point stencil (PLYcon2d / SPLOcnpJac compute kernel).

Row-band BlockSpec: each grid step owns a (bh, W) band plus one halo row
on each side — the VMEM incarnation of the neighbour-row reuse the L3
StencilSweep generator models (two of the three row reads per output block
are to rows another band also needs: the stencil's "remote" accesses).

Halo handling: rather than overlapping BlockSpecs (unsupported in
interpret mode), the kernel receives the *whole* padded array and slices
its band with dynamic indexing; bands stay VMEM-sized for realistic
shapes (W <= 4096 f32 => <= 16 KiB per row).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stencil_kernel(c_ref, p_ref, o_ref, *, bh):
    i = pl.program_id(0)
    r0 = i * bh
    # p_ref is the zero-padded array: p[r+1, c+1] == x[r, c].
    band_c = jax.lax.dynamic_slice_in_dim(p_ref[...], r0 + 1, bh, axis=0)
    band_n = jax.lax.dynamic_slice_in_dim(p_ref[...], r0, bh, axis=0)
    band_s = jax.lax.dynamic_slice_in_dim(p_ref[...], r0 + 2, bh, axis=0)
    center = band_c[:, 1:-1]
    north = band_n[:, 1:-1]
    south = band_s[:, 1:-1]
    west = band_c[:, :-2]
    east = band_c[:, 2:]
    coef = c_ref[...]
    o_ref[...] = coef[0] * center + coef[1] * (north + south + west + east)


@functools.partial(jax.jit, static_argnames=("bh",))
def stencil5(x, c_center=0.5, c_neigh=0.125, bh=32):
    """y = c_center*x + c_neigh*(N+S+E+W) with zero boundaries."""
    h, w = x.shape
    assert h % bh == 0, "height must tile by bh"
    p = jnp.pad(x, 1)
    coef = jnp.array([c_center, c_neigh], dtype=jnp.float32)
    grid = (h // bh,)
    return pl.pallas_call(
        functools.partial(_stencil_kernel, bh=bh),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((h + 2, w + 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bh, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(coef, p)

"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth
pytest compares against (the CORE correctness signal of the L1 layer)."""

import jax.numpy as jnp


def gemm_ref(a, b):
    """Plain matmul in f32."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def stencil5_ref(x, c_center=0.5, c_neigh=0.125):
    """5-point stencil with zero-padding boundaries.

    y[i,j] = c_center*x[i,j] + c_neigh*(x[i-1,j]+x[i+1,j]+x[i,j-1]+x[i,j+1])
    """
    p = jnp.pad(x, 1)
    return (
        c_center * x
        + c_neigh
        * (p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:])
    ).astype(x.dtype)


def triad_ref(b, c, scalar):
    """STREAM triad: a = b + scalar * c."""
    return b + scalar * c


def linreg_ref(x, y):
    """Least-squares slope & intercept via the moment sums the Phoenix
    map/reduce kernel accumulates."""
    n = x.shape[0]
    sx = jnp.sum(x)
    sy = jnp.sum(y)
    sxx = jnp.sum(x * x)
    sxy = jnp.sum(x * y)
    denom = n * sxx - sx * sx
    slope = (n * sxy - sx * sy) / denom
    intercept = (sy - slope * sx) / n
    return slope, intercept

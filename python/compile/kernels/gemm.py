"""Layer-1 Pallas GEMM kernel, tiled for the MXU/VMEM hierarchy.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): DL-PIM moves a DRAM
block next to the PIM core that reuses it; on a TPU-shaped machine the
same insight is the HBM->VMEM schedule. The BlockSpec below *is* a
subscription: grid step (i, j) reserves VMEM for one (bm, K) x (K, bn)
operand pair (the "reserved space"), pulls it local to the MXU, and
amortizes the transfer over bm*bn*K MACs of in-tile reuse — the analogue
of Fig 10's local-reuse count. Zero-reuse workloads (STREAM) gain nothing
from bigger tiles, the same crossover as the paper's Fig 9.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the Rust
runtime loads. On a real TPU the same kernel compiles natively.

VMEM budget at the default bm = bn = 64, K <= 512, f32:
  A tile 64*512*4 = 128 KiB, B tile 512*64*4 = 128 KiB, out 16 KiB
  => ~272 KiB per grid step, comfortably inside a 16 MiB VMEM with
  double-buffering headroom (DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref):
    # One (bm, K) x (K, bn) MXU contraction per grid step.
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def gemm(a, b, bm=64, bn=64):
    """C = A @ B with (bm, bn) output tiles; K is kept whole per step."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0, "dims must tile evenly"
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def gemm_tile(a, b):
    """Single-tile (64x64) multiply — the unit the Rust e2e driver calls
    through PJRT while the simulator replays its memory trace."""
    return gemm(a, b, bm=64, bn=64)
